"""Micro-batched kNN service throughput/latency vs the gather baseline,
plus the exact-vs-pruned routing A/B.

Drives runtime/knn_server.py with a closed-loop offered load (bursts of
requests with per-request l drawn from a fixed mix), for both
``sampler="selection"`` (Algorithm 2, O(log l) rounds) and
``sampler="gather"`` (the paper's simple method via knn_simple, O(k*l)
values on the wire) — the paper's Figure 2 contrast restated as a serving
benchmark.  A second section serves a *clustered* store (one cluster per
shard, queries near cluster centers) under ``route="exact"`` vs
``route="pruned"`` (store/summaries.py): same bit-identical answers,
fewer touched shards and k-machine messages.  A third section runs the
placement A/B (store/placement.py): the same clustered family streamed
into a *mutable* store under ``placement`` in {balance, affinity} x
``redeal`` in {round_robin, proximity}, measured before and after a
compaction, against a static cluster-contiguous pruned baseline — the
section that shows store-backed serving pruning like the static layout.
A fourth section runs the adaptive-maintenance A/B (store/adaptive.py)
on a *drifting-cluster* workload (cluster centers random-walk mid-stream
under sliding-window churn — repro.data.drifting_clusters): the same
stream under no maintenance vs scheduled re-tightening vs
re-tighten+split, measured *before* any compaction against a static
cluster-contiguous baseline of the final live set — the section that
shows pruned routing staying effective mid-stream instead of decaying
until the next compaction.  A fifth section exercises the observability
plane (src/repro/obs/): audited serving with tracing + contract +
shadow-exact checks on, the exported flight-recorder trace
(``--trace-out``), and the instrumented-vs-off overhead A/B — the
``obs`` block of the JSON.  A sixth section runs the in-shard index A/B
(store/index.py, DESIGN.md §13): ``search="exact"`` vs ``search="approx"``
over identical points and an identical query stream, on the clustered
AND the drifting workloads, with the recall floor and the >=3x
candidate-reduction target *hard-asserted* (ISSUE 8 acceptance) — the
``index`` block of the JSON, re-checked offline by
``benchmarks/check_obs.py``.  A seventh section runs the
label-prediction A/B (src/repro/predict/, DESIGN.md §15) on a labeled
Gaussian mixture with known Bayes-optimal labels: the exact vote fold
(hard-asserted bit-identical to the single-machine oracle) against the
one-message-per-shard ensemble (hard-asserted onto rounds == 1 and
messages == shards_touched per query, accuracy >= the configured
floor, accuracy-mode shadow audit clean) — the ``predict`` block of
the JSON with its accuracy-vs-message-bill table, re-checked offline
by ``check_obs.py check_predict``.  The operator layer (ISSUE 9) rides the
same sections: the obs server runs a deliberately impossible latency
SLO that must fire and clear (burn-rate engine, obs/slo.py), serves its
metrics over an ephemeral HTTP endpoint whose Prometheus text is
round-tripped and written to ``--prom-out``, the clustered approx arm
attaches one query-explain report whose kept-bucket set must match the
recomputed keep rule, and every run appends one stamped summary row to
the tracked perf ledger (``--history``, benchmarks/perf_ledger.py) that
``benchmarks/check_perf.py`` judges against a rolling baseline.  Emits
CSV rows like every other bench module plus ``BENCH_serve.json`` with
sustained queries/sec, p50/p99 request latency, and mean
rounds/messages/shards_touched per configuration.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src:. python benchmarks/bench_serve.py --out BENCH_serve.json
"""

try:
    from benchmarks import common  # noqa: F401  (claims the 8-device mesh)
    from benchmarks import perf_ledger
except ImportError:  # run as a plain script: python benchmarks/bench_serve.py
    import common
    import perf_ledger

import argparse
import json
import time

import numpy as np

from repro.configs.knn_service import CONFIG


# CPU-sized service shape: big enough that a datastore pass dominates the
# python batching overhead, small enough that the bench stays in seconds.
N_POINTS = common.K_MACHINES * 4096
DIM = 32
L_MAX = 32
L_MIX = (1, 4, 8, 32)          # per-request l rotation
BUCKETS = (1, 2, 4, 8, 16)
BURSTS = 24                    # measured dispatch bursts per sampler
WARM_BURSTS = 3


def _build_server(sampler: str, n_points: int):
    from repro.runtime import KnnServer
    rng = np.random.default_rng(0)
    pts = rng.normal(size=(n_points, DIM)).astype(np.float32)
    cfg = CONFIG.replace(
        dim=DIM, l=8, l_max=L_MAX, bucket_sizes=BUCKETS, sampler=sampler)
    srv = KnnServer(pts, cfg=cfg, mesh=common.kmachine_mesh(),
                    axis_name="x")
    srv.warmup()
    return srv


def _build_routed_server(route: str, n_points: int):
    """Clustered store, one cluster per shard (contiguous layout), for
    the exact-vs-pruned routing A/B — the same instance family the
    exactness harness proves bit-identical (repro.data.sharded_clusters)."""
    from repro.data import sharded_clusters
    from repro.runtime import KnnServer
    k = common.K_MACHINES
    pts, centers = sharded_clusters(k, n_points // k, DIM, seed=1)
    cfg = CONFIG.replace(dim=DIM, l=8, l_max=L_MAX, bucket_sizes=BUCKETS,
                         sampler="selection", route=route)
    srv = KnnServer(pts, cfg=cfg, mesh=common.kmachine_mesh(),
                    axis_name="x")
    srv.warmup()
    return srv, centers


def _build_placement_store(placement: str, redeal: str, pts, order,
                           delete_ids, cap: int, staging: int):
    """Stream the clustered points (cluster-interleaved order) into a
    mutable store under one placement policy, with a delete wave at the
    end — the streaming-ingest workload of the placement A/B.  Ids are
    assigned 0..n-1 in stream order; ``delete_ids`` names the wave, so
    every variant (and the static baseline) serves the identical
    post-delete live set."""
    from repro.store import MutableStore
    cfg = CONFIG.replace(placement=placement, redeal=redeal,
                         store_capacity_per_shard=cap,
                         store_staging_size=staging)
    store = MutableStore(DIM, mesh=common.kmachine_mesh(), axis_name="x",
                         **cfg.store_kwargs())
    shuffled = pts[order]
    for i in range(0, len(shuffled), staging):
        store.insert(shuffled[i:i + staging])
        store.flush()
    store.delete(delete_ids)        # 12.5% churn: tombstones + size drift
    store.flush()
    return store


def _placement_section(bursts: int, per_shard: int, emit) -> dict:
    """Placement A/B: store-backed pruned serving vs the static layout.

    Every variant serves the identical live point set (same clustered
    family, same delete wave) and the identical query stream, so
    shards_touched differences are purely the layout's doing.  Each
    store variant is measured twice: after streaming ingest
    (pre_compact) and after one compaction (post_compact) — the point
    where ``redeal="round_robin"`` smears whatever locality affinity
    placement built, and ``redeal="proximity"`` restores it.
    """
    from repro.data import sharded_clusters
    from repro.runtime import KnnServer
    k = common.K_MACHINES
    pts, centers = sharded_clusters(k, per_shard, DIM, seed=5)
    order = np.random.default_rng(5).permutation(len(pts))
    cap, staging = per_shard * 2, max(64, per_shard // 8)
    cfg = CONFIG.replace(dim=DIM, l=8, l_max=L_MAX, bucket_sizes=BUCKETS,
                         sampler="selection", route="pruned")
    section = {"per_shard": per_shard, "capacity_per_shard": cap,
               "staging": staging, "delete_frac": 1 / 8}

    # The delete wave drops an eighth of *each cluster* (uniform churn):
    # id i holds original row order[i], so deleting the ids whose row
    # falls in the first per_shard/8 of its cluster block leaves every
    # cluster at exactly 7/8 size.  That makes the post-delete live set
    # identical across the store variants AND expressible as a
    # cluster-contiguous static layout with k-divisible equal blocks —
    # the baseline below serves the very same points, so shards_touched
    # differences are purely the layout's doing.
    dropped_rows = (np.arange(len(pts)) % per_shard) < per_shard // 8
    delete_ids = np.flatnonzero(dropped_rows[order])
    static_pts = pts[~dropped_rows]

    # static cluster-contiguous reference: the layout PR 3's routing win
    # was demonstrated on
    srv = KnnServer(static_pts, cfg=cfg, mesh=common.kmachine_mesh(),
                    axis_name="x")
    srv.warmup()
    section["static_pruned"] = _drive(
        srv, np.random.default_rng(13), bursts, centers=centers)
    section["static_pruned"]["placement_stats"] = srv.placement_stats()
    static_touched = section["static_pruned"]["mean_shards_touched"]
    emit(common.row(
        "serve_placement_static_pruned",
        1e6 / section["static_pruned"]["qps"],
        f"shards_touched={static_touched:.2f}"))

    for placement, redeal in (("balance", "round_robin"),
                              ("affinity", "round_robin"),
                              ("affinity", "proximity")):
        name = f"{placement}+{redeal}"
        store = _build_placement_store(placement, redeal, pts, order,
                                       delete_ids, cap, staging)
        srv = KnnServer(store=store, cfg=cfg)
        srv.warmup()
        entry = {"pre_compact": _drive(srv, np.random.default_rng(13),
                                       bursts, centers=centers)}
        entry["pre_compact"]["placement_stats"] = srv.placement_stats()
        store.compact()
        entry["post_compact"] = _drive(srv, np.random.default_rng(13),
                                       bursts, centers=centers)
        entry["post_compact"]["placement_stats"] = srv.placement_stats()
        entry["compactions"] = store.stats.compactions
        entry["vs_static_touched_ratio"] = (
            entry["post_compact"]["mean_shards_touched"]
            / max(static_touched, 1e-9))
        section[name] = entry
        emit(common.row(
            f"serve_placement_{placement}_{redeal}",
            1e6 / entry["post_compact"]["qps"],
            f"touched_pre={entry['pre_compact']['mean_shards_touched']:.2f} "
            f"touched_post={entry['post_compact']['mean_shards_touched']:.2f} "
            f"msgs={entry['post_compact']['mean_messages']:.1f} "
            f"prune_rate="
            f"{entry['post_compact']['placement_stats']['prune_rate']:.2f}"))
    return section


def _stream_drift(store, pts_steps, window: int, staging: int):
    """Sliding-window churn: step s inserts that step's points (chunked
    so the write-ahead buffer flushes several generations per step — the
    cadence the one-shard-per-flush re-tightening schedule amortizes
    over) and deletes step s-window's, so the live set is always the
    last ``window`` steps of the walk."""
    ids_by_step = []
    for s, pts in enumerate(pts_steps):
        step_ids = []
        for i in range(0, len(pts), staging):
            step_ids.append(store.insert(pts[i:i + staging]))
        ids_by_step.append(np.concatenate(step_ids))
        if s >= window:
            store.delete(ids_by_step[s - window])
        store.flush()


def _adaptive_section(bursts: int, per_step: int, steps: int, window: int,
                      retighten_every: int, emit) -> dict:
    """Adaptive maintenance A/B on the drifting-cluster workload.

    Every variant ingests the identical seeded stream (same points, same
    sliding-window deletes) into an affinity+proximity store with
    ``auto_compact=False`` — so the *pre_compact* measurement shows what
    the summaries alone can still prune mid-stream, with no compaction
    rebuild to the rescue.  Without maintenance the incremental covering
    radii span the whole walked path and pruning decays toward all-k;
    re-tightening shrinks them back to the live window, and the split
    trigger re-deals shards whose homes the walk left stale.  The static
    baseline serves the identical final live set cluster-contiguously —
    the acceptance yardstick (ISSUE 5: adaptive pre-compact within 2x).
    """
    from repro.data import drifting_clusters
    from repro.runtime import KnnServer
    from repro.store import MutableStore
    k = common.K_MACHINES
    stream = list(drifting_clusters(k, per_step, DIM, steps=steps,
                                    drift=8.0, seed=17))
    pts_steps = [pts for pts, _ in stream]
    final_centers = stream[-1][1]
    cap = (steps + 2) * per_step
    staging = max(32, per_step)
    cfg = CONFIG.replace(dim=DIM, l=8, l_max=L_MAX, bucket_sizes=BUCKETS,
                         sampler="selection", route="pruned",
                         placement="affinity", redeal="proximity",
                         store_capacity_per_shard=cap,
                         store_staging_size=staging, summary_pivots=2)
    section = {"per_step": per_step, "steps": steps, "window": window,
               "drift": 8.0, "capacity_per_shard": cap,
               "retighten_every": retighten_every}

    # static cluster-contiguous reference over the final live set (the
    # last `window` steps of each cluster's walk, grouped by cluster)
    static_pts = np.concatenate(
        [np.concatenate([pts_steps[s][c * per_step:(c + 1) * per_step]
                         for s in range(steps - window, steps)])
         for c in range(k)])
    srv = KnnServer(static_pts, cfg=cfg, mesh=common.kmachine_mesh(),
                    axis_name="x")
    srv.warmup()
    section["static_pruned"] = _drive(
        srv, np.random.default_rng(23), bursts, centers=final_centers)
    static_touched = section["static_pruned"]["mean_shards_touched"]
    emit(common.row("serve_adaptive_static_pruned",
                    1e6 / section["static_pruned"]["qps"],
                    f"shards_touched={static_touched:.2f}"))

    variants = (
        ("none", dict(retighten_every=0, split_radius_factor=0.0)),
        ("retighten", dict(retighten_every=retighten_every,
                           split_radius_factor=0.0)),
        ("retighten_split", dict(retighten_every=retighten_every,
                                 split_radius_factor=1.0)),
    )
    for name, knobs in variants:
        vcfg = cfg.replace(**knobs)
        store = MutableStore(DIM, mesh=common.kmachine_mesh(),
                             axis_name="x", auto_compact=False,
                             **vcfg.store_kwargs())
        _stream_drift(store, pts_steps, window, staging)
        srv = KnnServer(store=store, cfg=vcfg)
        srv.warmup()
        entry = {"pre_compact": _drive(srv, np.random.default_rng(23),
                                       bursts, centers=final_centers)}
        entry["pre_compact"]["placement_stats"] = srv.placement_stats()
        store.compact()
        entry["post_compact"] = _drive(srv, np.random.default_rng(23),
                                       bursts, centers=final_centers)
        entry["post_compact"]["placement_stats"] = srv.placement_stats()
        entry["retightens"] = store.stats.retightens
        entry["splits"] = store.stats.splits
        # the pre_compact claim rests on NO other exact rebuild having
        # run: auto_compact is off, but a full-shard mid-flush forced
        # repack would rebuild summaries silently — fail loudly instead
        # of recording an invalid measurement if sizing ever trips it.
        entry["forced_compactions"] = store.stats.forced_compactions
        assert store.stats.forced_compactions == 0, (
            f"{name}: forced repack contaminated the pre_compact "
            f"measurement — grow capacity_per_shard")
        entry["pre_vs_static_touched_ratio"] = (
            entry["pre_compact"]["mean_shards_touched"]
            / max(static_touched, 1e-9))
        section[name] = entry
        emit(common.row(
            f"serve_adaptive_{name}", 1e6 / entry["pre_compact"]["qps"],
            f"touched_pre={entry['pre_compact']['mean_shards_touched']:.2f} "
            f"touched_post={entry['post_compact']['mean_shards_touched']:.2f} "
            f"ratio_vs_static={entry['pre_vs_static_touched_ratio']:.2f} "
            f"retightens={entry['retightens']} splits={entry['splits']} "
            f"max_slack="
            f"{entry['pre_compact']['placement_stats']['max_summary_slack']:.2f}"))
    section["forced_tiny"] = _forced_tiny_adaptive()
    emit(common.row(
        "serve_adaptive_forced_tiny", 0.0,
        f"splits={section['forced_tiny']['splits']} "
        f"retightens={section['forced_tiny']['retightens']}"))
    return section


def _forced_tiny_adaptive() -> dict:
    """The CI smoke hook (make bench-smoke): one *forced* split and one
    *forced* re-tightening on a tiny store, hard-asserted — two
    interleaved far-apart lumps under balance placement smear every
    shard (radius >> centroid gap), so split_radius_factor=1 must fire
    on the first flush; retighten_every=1 must re-tighten on the first
    flush of its store.  Deterministic; a silent regression of either
    trigger fails the bench, not just a number."""
    from repro.store import MutableStore
    rng = np.random.default_rng(3)
    pts = np.empty((128, DIM), np.float32)
    pts[0::2] = (rng.normal(size=(64, DIM)) + 40).astype(np.float32)
    pts[1::2] = (rng.normal(size=(64, DIM)) - 40).astype(np.float32)

    def mk(**knobs):
        s = MutableStore(DIM, mesh=common.kmachine_mesh(), axis_name="x",
                         capacity_per_shard=64, summary_pivots=2,
                         placement="balance", auto_compact=False, **knobs)
        s.insert(pts)
        s.flush()
        return s

    split_store = mk(split_radius_factor=1.0)
    tight_store = mk(retighten_every=1)
    out = {"splits": split_store.stats.splits,
           "retightens": tight_store.stats.retightens,
           "post_split_max_radius": float(
               split_store.summaries().radii.max())}
    assert out["splits"] >= 1, "split trigger failed to fire"
    assert out["retightens"] >= 1, "re-tighten schedule failed to fire"
    return out


def _obs_section(bursts: int, per_shard: int, emit, trace_out=None,
                 prom_out=None) -> dict:
    """Observability section (DESIGN.md §12): the flight recorder priced
    and proved on the serving plane.

    One store-backed clustered server with the full obs surface on —
    ``obs_trace=True`` + ``obs_audit_every=4`` over a pruned,
    device-routed, ``maintenance="background"`` store — serves query
    bursts interleaved with drifting ingest waves, so the exported trace
    (``--trace-out``) holds complete request/dispatch span trees *racing*
    maintenance plan/prepare/commit cycles.  The section reports the
    audited numbers (Theorem-1 contract checks, shadow-exact replays,
    per-stage p50/p99 from the unified registry) and an instrumented-vs-
    off A/B on the plain selection workload: same seeds, tracing +
    contract auditing on vs the no-op plane, best-of-3 qps per arm —
    the acceptance gate is <= 10% overhead (``make obs-smoke`` /
    tests/test_obs.py assert the contract+shadow zeros and the trace's
    well-formedness; the overhead guard lives in the test suite where
    it can retry, not here where one noisy CPU run would gate CI).

    The same server also proves the operator layer end-to-end (ISSUE 9):
    a deliberately impossible latency SLO (``slo_latency_p99_s=1e-6``)
    makes every request a bad event, so the burn-rate engine must fire
    during serving and clear once the fast window drains after quiesce —
    the section asserts >= 1 alert fired AND cleared, and exports the
    trace *after* the clear so the ``slo.alert`` span lands in the
    artifact.  The metrics endpoint is bound on an ephemeral port
    (``obs_http_port=-1``) and the Prometheus text actually served over
    HTTP is round-tripped through ``parse_prometheus_text`` and written
    to ``--prom-out`` for the ``check_obs`` gate.
    """
    from repro.data import sharded_clusters
    from repro.runtime import KnnServer
    from repro.store import MutableStore
    k = common.K_MACHINES
    pts, centers = sharded_clusters(k, per_shard, DIM, seed=29)
    cap, staging = per_shard * 4, max(16, per_shard // 4)
    cfg = CONFIG.replace(
        dim=DIM, l=8, l_max=L_MAX, bucket_sizes=BUCKETS,
        sampler="selection", route="pruned", route_compute="device",
        summary_pivots=2, placement="affinity", redeal="proximity",
        retighten_every=4, split_radius_factor=1.2,
        maintenance="background",
        store_capacity_per_shard=cap, store_staging_size=staging,
        obs_trace=True, obs_audit_every=4,
        # forced-breach SLO: no request finishes in a microsecond, so
        # every event is bad and the alert must fire mid-serving
        slo_latency_p99_s=1e-6, slo_fast_window_s=0.4,
        slo_slow_window_s=1.2,
        obs_http_port=-1)
    store = MutableStore(DIM, mesh=common.kmachine_mesh(), axis_name="x",
                         **cfg.store_kwargs())
    order = np.random.default_rng(29).permutation(len(pts))
    shuffled = pts[order]
    for i in range(0, len(shuffled), staging):
        store.insert(shuffled[i:i + staging])
        store.flush()
    srv = KnnServer(store=store, cfg=cfg)
    srv.warmup()

    # Serving loop: each burst's queries land near one center; between
    # bursts an ingest wave (insert into a drifted cluster + delete the
    # oldest wave) lands two epoch swaps and makes shards due — the
    # background worker re-tightens mid-stream, so maint.* spans
    # interleave with request spans in the very same ring.
    rng = np.random.default_rng(31)
    drifted = centers.copy()
    waves, lat = [], []
    n_queries = 0
    t0 = time.perf_counter()
    for burst in range(max(bursts, 6)):
        bs = [1, 3, 8, 4][burst % 4]
        c = int(rng.integers(0, k))
        qs = (drifted[c] + rng.normal(size=(bs, DIM))).astype(np.float32)
        ls = [L_MIX[(burst + j) % len(L_MIX)] for j in range(bs)]
        for r in srv.query_batch(qs, ls):
            lat.append(r.latency_s)
        n_queries += bs
        drifted[c] += rng.normal(size=DIM) * 0.5
        waves.append(store.insert(
            (drifted[c] + rng.normal(size=(staging // 2, DIM)))
            .astype(np.float32)))
        store.flush()
        if len(waves) > 2:
            store.delete(waves.pop(0))
            store.flush()
    wall = time.perf_counter() - t0
    # the trace artifact must show a *committed* maintenance cycle racing
    # the queries above; the worker is event-driven, so give it a bounded
    # window to drain before the join
    deadline = time.perf_counter() + 60
    while (store.maintenance_stats()["worker"]["commits"] == 0
           and time.perf_counter() < deadline):
        time.sleep(0.02)
    store.close()        # joins the worker; any staged cycle lands first
    worker = store.maintenance_stats()["worker"]
    assert worker["errors"] == 0, worker["error"]
    assert worker["commits"] > 0, (
        "no maintenance commit landed in the obs trace window")
    assert srv.obs.tracer.active_count() == 0, "torn spans after quiesce"

    section = {
        "queries": n_queries,
        "qps": n_queries / wall,
        "p50_ms": float(np.percentile(np.asarray(lat), 50) * 1e3),
        "route": cfg.route, "route_compute": cfg.route_compute,
        "maintenance": cfg.maintenance,
        "obs_audit_every": cfg.obs_audit_every,
        "maintenance_commits": worker["commits"],
    }
    section.update(common.obs_section(srv))
    assert section["contract_checks"] > 0 and section["shadow_checks"] > 0

    # SLO verdict: the impossible latency objective must have fired
    # during serving, and must clear once the fast window drains after
    # quiesce.  Poll obs_snapshot — every snapshot re-evaluates, so the
    # clear lands as soon as the window ages out.
    slo_deadline = time.perf_counter() + 15
    slo = srv.obs_snapshot()["slo"]
    while (slo["alerts_cleared"] == 0
           and time.perf_counter() < slo_deadline):
        time.sleep(0.1)
        slo = srv.obs_snapshot()["slo"]
    assert slo["alerts_fired"] >= 1, "forced-breach SLO never fired"
    assert slo["alerts_cleared"] >= 1, "forced-breach SLO never cleared"
    assert not slo["firing"], f"still firing after drain: {slo['firing']}"
    section["slo"] = slo

    # Prometheus exposition fetched over the wire from the ephemeral
    # endpoint this server bound, round-tripped through the strict
    # parser, and written out for the check_obs gate.
    from urllib.request import urlopen
    from repro.obs.export import parse_prometheus_text
    with urlopen(f"http://127.0.0.1:{srv._http.port}/metrics",
                 timeout=10) as resp:
        prom_text = resp.read().decode("utf-8")
    parsed = parse_prometheus_text(prom_text)
    assert "knn_serve_latency_s" in parsed, sorted(parsed)[:8]
    section["prometheus"] = {"metrics": len(parsed)}
    if prom_out:
        with open(prom_out, "w") as f:
            f.write(prom_text)
        section["prometheus"]["path"] = prom_out
        emit(f"# wrote {prom_out} ({len(parsed)} metrics)")

    # Export the trace AFTER the clear so the slo.fire / slo.clear /
    # slo.alert spans are part of the artifact check_obs validates.
    if trace_out:
        n_spans = srv.export_trace_jsonl(trace_out)
        section["trace_out"] = {"path": trace_out, "spans": n_spans}
        emit(f"# wrote {trace_out} ({n_spans} spans)")
    srv.close()

    # Instrumented-vs-off overhead A/B (static selection server, the
    # simplest repeatable workload): arm "on" = tracing + contract
    # auditing (shadow audit off — it *replays* kernels by design, so it
    # is priced by obs_audit_every, not by the recorder).  The arms run
    # *interleaved*, best-of-3 each: back-to-back arms confound the
    # recorder's few-microsecond cost with scheduler/thermal drift
    # across the minutes-long bench, which dwarfs it.
    def arm(obs_on: bool):
        arm_rng = np.random.default_rng(0)
        arm_pts = arm_rng.normal(size=(k * per_shard, DIM)) \
            .astype(np.float32)
        arm_cfg = CONFIG.replace(dim=DIM, l=8, l_max=L_MAX,
                                 bucket_sizes=BUCKETS, sampler="selection",
                                 obs_trace=obs_on)
        arm_srv = KnnServer(arm_pts, cfg=arm_cfg,
                            mesh=common.kmachine_mesh(), axis_name="x")
        arm_srv.warmup()
        return arm_srv

    srv_off, srv_on = arm(False), arm(True)
    qps_off = qps_on = 0.0
    for _ in range(3):
        qps_off = max(qps_off, _drive(srv_off, np.random.default_rng(41),
                                      bursts)["qps"])
        qps_on = max(qps_on, _drive(srv_on, np.random.default_rng(41),
                                    bursts)["qps"])
    section["overhead"] = {
        "qps_off": qps_off, "qps_on": qps_on,
        "overhead_frac": (qps_off - qps_on) / qps_off,
    }
    emit(common.row(
        "serve_obs_audited", 1e6 / section["qps"],
        f"contract={section['contract_checks']}/"
        f"{section['contract_violations']}viol "
        f"shadow={section['shadow_checks']}/"
        f"{section['shadow_divergences']}div "
        f"commits={worker['commits']}"))
    emit(common.row(
        "serve_obs_overhead", 1e6 / qps_on,
        f"qps_on={qps_on:.1f} qps_off={qps_off:.1f} "
        f"overhead={100 * section['overhead']['overhead_frac']:.1f}%"))
    return section


def _index_ab(srv_exact, srv_approx, centers, bursts: int) -> dict:
    """One exact-vs-approx arm: drive both servers under the identical
    closed-loop load (throughput/latency numbers), then sweep the *same*
    queries through both and measure recall@l of the approx answers
    against the exact twin's — the exact arm IS the ground truth, so no
    separate oracle pass is needed.  Candidate reduction is read off the
    approx server's ``serve.candidate_fraction`` histogram (observed by
    every dispatch, no device readback)."""
    sentinel = 2 ** 31 - 1
    entry = {"exact": _drive(srv_exact, np.random.default_rng(47),
                             bursts, centers=centers),
             "approx": _drive(srv_approx, np.random.default_rng(47),
                              bursts, centers=centers)}
    rng = np.random.default_rng(53)
    recalls = []
    for burst in range(max(bursts, 6)):
        bs = [1, 3, 8, 5][burst % 4]
        qs = (centers[int(rng.integers(0, len(centers)))]
              + rng.normal(size=(bs, DIM))).astype(np.float32)
        ls = [L_MIX[(burst + j) % len(L_MIX)] for j in range(bs)]
        for re_, ra in zip(srv_exact.query_batch(qs, ls),
                           srv_approx.query_batch(qs, ls)):
            assert re_.recall_mode == "exact"
            assert ra.recall_mode == "approx"
            truth = set(re_.ids[re_.ids != sentinel].tolist())
            if truth:
                recalls.append(len(truth & set(ra.ids.tolist()))
                               / len(truth))
    snap = srv_approx.obs_snapshot()
    cf = snap["metrics"]["serve.candidate_fraction"]
    shadow = snap["audit"]["shadow"]
    entry.update({
        "recall_count": len(recalls),
        "recall_min": float(min(recalls)),
        "recall_mean": float(np.mean(recalls)),
        "candidate_fraction_mean": cf["mean"],
        "candidate_reduction": 1.0 / max(cf["mean"], 1e-9),
        "shadow": {"mode": shadow["mode"], "floor": shadow.get("floor"),
                   "checks": shadow["checks"],
                   "divergences": shadow["divergences"],
                   "recall": shadow.get("recall")},
    })
    return entry


def _index_section(bursts: int, per_shard: int, per_step: int, steps: int,
                   window: int, emit) -> dict:
    """In-shard index A/B (store/index.py, DESIGN.md §13) — the section
    that *enforces* the approximation's measured contract instead of
    merely reporting it.

    Two arms, each ``search="exact"`` vs ``search="approx"`` over the
    identical points and query stream:

    * **clustered** — the static cluster-contiguous layout routing
      already prunes to ~1 shard; the bucket index must now prune
      *within* the shard.  Hard gates (ISSUE 8 acceptance): measured
      recall@l >= the configured floor AND candidate reduction >= 3x
      (mean candidate fraction <= 1/3).
    * **drifting** — the adaptive-maintenance workload: a drifting
      cluster stream under sliding-window churn into a mutable store,
      the index maintained *incrementally* across flush generations (no
      compaction rebuild to the rescue).  The recall floor is enforced
      here too — the keep rule stays sound under ball inflation — but
      the reduction is reported, not gated: drift legitimately inflates
      balls (less pruning) until maintenance catches up.

    Both approx arms run the shadow auditor in ``mode="recall"``
    (obs_audit_every=4), so the live audit measures the same contract
    the offline sweep does; ``benchmarks/check_obs.py`` re-asserts all
    of it from the JSON artifact.
    """
    from repro.data import drifting_clusters, sharded_clusters
    from repro.runtime import KnnServer
    from repro.store import MutableStore
    k = common.K_MACHINES
    buckets = 8
    cfg = CONFIG.replace(dim=DIM, l=8, l_max=L_MAX, bucket_sizes=BUCKETS,
                         sampler="selection", route="pruned")
    acfg = cfg.replace(search="approx", index_buckets=buckets,
                       obs_audit_every=4)
    section = {"per_shard": per_shard, "index_buckets": buckets,
               "index_oversample": acfg.index_oversample,
               "recall_floor": acfg.recall_floor}

    # clustered arm: static layout, one cluster per shard
    pts, centers = sharded_clusters(k, per_shard, DIM, seed=43)
    se = KnnServer(pts, cfg=cfg, mesh=common.kmachine_mesh(),
                   axis_name="x")
    sa = KnnServer(pts, cfg=acfg, mesh=common.kmachine_mesh(),
                   axis_name="x")
    se.warmup()
    sa.warmup()
    arm = _index_ab(se, sa, centers, bursts)
    section["clustered"] = arm
    assert arm["recall_min"] >= acfg.recall_floor, (
        f"clustered recall@l {arm['recall_min']:.3f} below the "
        f"{acfg.recall_floor} floor")
    assert arm["candidate_reduction"] >= 3.0, (
        f"clustered candidate reduction {arm['candidate_reduction']:.2f}x "
        f"below the 3x target")
    assert arm["shadow"]["divergences"] == 0, arm["shadow"]

    # Operator-layer demo (ISSUE 9): the last routed approx query of
    # the recall sweep explains itself, and the report's kept-bucket
    # set must agree with a from-scratch recompute of the keep rule
    # (ExplainRecord.build re-runs routing_detail + bucket_keep on the
    # captured snapshot and compares — ``kept_matches_recompute``).
    rep = sa.explain_last(1)[0]
    assert rep["schema"] == "knn.explain.v1", rep["schema"]
    assert rep["routing"]["mode"] == "pruned", rep["routing"]
    assert rep["request"]["recall_mode"] == "approx", rep["request"]
    assert rep["index"]["enabled"], rep["index"]
    assert rep["index"]["kept_matches_recompute"], rep["index"]
    section["explain"] = rep
    emit(f"# explain: row {rep['request']['row']} kept "
         f"{len(rep['routing']['kept_shards'])}/{common.K_MACHINES} shards, "
         f"{len(rep['index']['kept_buckets'])} buckets, recompute match")

    emit(common.row(
        "serve_index_clustered_approx", 1e6 / arm["approx"]["qps"],
        f"recall_min={arm['recall_min']:.3f} "
        f"cand_frac={arm['candidate_fraction_mean']:.3f} "
        f"reduction={arm['candidate_reduction']:.1f}x "
        f"qps_exact={arm['exact']['qps']:.1f} "
        f"qps_approx={arm['approx']['qps']:.1f}"))

    # drifting arm: mutable store, index maintained across generations;
    # both servers share the store, so the live set is identical by
    # construction (an exact-search server on an indexed store simply
    # ignores the index)
    stream = list(drifting_clusters(k, per_step, DIM, steps=steps,
                                    drift=8.0, seed=59))
    pts_steps = [p for p, _ in stream]
    final_centers = stream[-1][1]
    cap = (steps + 2) * per_step
    staging = max(32, per_step)
    dcfg = acfg.replace(placement="affinity", redeal="proximity",
                        retighten_every=4, summary_pivots=2,
                        store_capacity_per_shard=cap,
                        store_staging_size=staging)
    store = MutableStore(DIM, mesh=common.kmachine_mesh(), axis_name="x",
                         **dcfg.store_kwargs())
    _stream_drift(store, pts_steps, window, staging)
    se_d = KnnServer(store=store, cfg=dcfg.replace(search="exact"))
    sa_d = KnnServer(store=store, cfg=dcfg)
    se_d.warmup()
    sa_d.warmup()
    arm_d = _index_ab(se_d, sa_d, final_centers, bursts)
    arm_d.update({"per_step": per_step, "steps": steps, "window": window})
    section["drifting"] = arm_d
    assert arm_d["recall_min"] >= acfg.recall_floor, (
        f"drifting recall@l {arm_d['recall_min']:.3f} below the "
        f"{acfg.recall_floor} floor")
    assert arm_d["shadow"]["divergences"] == 0, arm_d["shadow"]
    emit(common.row(
        "serve_index_drifting_approx", 1e6 / arm_d["approx"]["qps"],
        f"recall_min={arm_d['recall_min']:.3f} "
        f"cand_frac={arm_d['candidate_fraction_mean']:.3f} "
        f"reduction={arm_d['candidate_reduction']:.1f}x "
        f"qps_exact={arm_d['exact']['qps']:.1f} "
        f"qps_approx={arm_d['approx']['qps']:.1f}"))
    return section


def _oracle_votes(pts, labels, qs, ls, num_classes: int) -> np.ndarray:
    """Single-machine oracle vote per query: f64 distances, stable sort,
    ties toward the lowest class — the ground truth the exact predict
    arm must match bit-for-bit (tests/test_predict.py pins the same
    oracle across every route/compute/search mode)."""
    d = ((qs[:, None, :].astype(np.float64)
          - pts[None].astype(np.float64)) ** 2).sum(-1)
    out = np.empty(len(qs), np.float32)
    for i, (row, l) in enumerate(zip(d, ls)):
        idx = np.argsort(row, kind="stable")[:l]
        out[i] = np.bincount(labels[idx], minlength=num_classes).argmax()
    return out


def _drive_predict(srv, bursts: int, centers, num_classes: int,
                   *, oracle=None) -> dict:
    """Closed-loop labeled load: queries are fresh draws from the same
    mixture (component label known), so every answer is scored against
    the Bayes-optimal label; with ``oracle`` (the (pts, labels) pair)
    every exact answer is additionally hard-asserted bit-identical to
    the single-machine vote.  Ensemble answers are hard-asserted onto
    the one-message-per-shard bill: rounds == 1 and messages ==
    shards_touched on every query."""
    from repro.data import bayes_labels
    rng = np.random.default_rng(29)          # same load on every arm
    burst_sizes = [1, 3, 8, 16, 5, 16, 2, 16]
    lat, msgs, rounds, touched = [], [], [], []
    correct = total = oracle_mismatches = 0
    ensemble = srv.cfg.predict_mode == "ensemble"
    t0 = None
    for burst in range(WARM_BURSTS + bursts):
        if burst == WARM_BURSTS:
            t0 = time.perf_counter()
        bs = burst_sizes[burst % len(burst_sizes)]
        qlab = rng.integers(0, num_classes, bs)
        qs = (centers[qlab] + rng.normal(size=(bs, DIM))).astype(np.float32)
        ls = [L_MIX[(burst + j) % len(L_MIX)] for j in range(bs)]
        results = srv.query_batch(qs, ls)
        if burst < WARM_BURSTS:
            continue
        truth = bayes_labels(qs, centers)
        want = (None if oracle is None else
                _oracle_votes(oracle[0], oracle[1], qs, ls, num_classes))
        for j, r in enumerate(results):
            lat.append(r.latency_s)
            msgs.append(r.messages)
            rounds.append(r.rounds)
            touched.append(r.shards_touched)
            total += 1
            correct += int(r.label == truth[j])
            if want is not None and r.label != want[j]:
                oracle_mismatches += 1
            if ensemble:
                assert r.rounds == 1 and r.messages == r.shards_touched, (
                    f"ensemble bill broken: rounds={r.rounds} "
                    f"messages={r.messages} touched={r.shards_touched}")
    wall = time.perf_counter() - t0
    lat = np.asarray(lat)
    return {
        "queries": total,
        "qps": total / wall,
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "accuracy": correct / total,
        "oracle_mismatches": (None if oracle is None
                              else oracle_mismatches),
        "mean_messages": float(np.mean(msgs)),
        "mean_rounds": float(np.mean(rounds)),
        "mean_shards_touched": float(np.mean(touched)),
        "bill_messages_eq_touched": bool(ensemble),
    }


def _predict_section(bursts: int, n_per_class: int, emit) -> dict:
    """Label-prediction A/B (src/repro/predict/, DESIGN.md §15): the
    exact fold vs one-message-per-shard ensemble on a labeled Gaussian
    mixture with known Bayes-optimal labels (data/synthetic.py
    ``labeled_mixture``).  Hard-asserts the PR's accuracy-vs-message-
    bill contract inline (check_obs.py ``check_predict`` re-asserts it
    from the JSON artifact):

      * exact arm — every served label bit-identical to the
        single-machine oracle vote (zero mismatches tolerated);
      * ensemble arm — rounds == 1 and messages == shards_touched on
        every query, accuracy >= the configured ``accuracy_floor``, and
        the accuracy-mode shadow auditor active with zero flagged
        batches.

    The ``bill`` table prices what the ensemble's O(C)-message protocol
    costs in accuracy against what the exact fold's extra round buys.
    """
    from repro.data import labeled_mixture
    from repro.runtime import KnnServer
    num_classes = 4
    n = num_classes * n_per_class
    pts, labels, centers = labeled_mixture(
        n, DIM, num_classes, separation=8.0, seed=23)
    base = CONFIG.replace(
        dim=DIM, l=8, l_max=L_MAX, bucket_sizes=BUCKETS,
        sampler="selection", num_classes=num_classes, predict="vote",
        route="pruned", route_compute="host", obs_audit_every=2)
    section = {"n_points": n, "num_classes": num_classes,
               "separation": 8.0, "accuracy_floor": base.accuracy_floor}

    arms = (("exact", base.replace(predict_mode="exact")),
            ("ensemble", base.replace(predict_mode="ensemble")),
            ("ensemble_k1", base.replace(predict_mode="ensemble",
                                         local_k=1)))
    for name, cfg in arms:
        srv = KnnServer(pts, labels=labels, cfg=cfg,
                        mesh=common.kmachine_mesh(), axis_name="x")
        srv.warmup()
        oracle = (pts, labels) if name == "exact" else None
        arm = _drive_predict(srv, bursts, centers, num_classes,
                             oracle=oracle)
        arm["local_k"] = cfg.local_k
        if name == "exact":
            assert arm["oracle_mismatches"] == 0, (
                f"exact predict diverged from the single-machine oracle "
                f"on {arm['oracle_mismatches']} queries")
        else:
            assert arm["accuracy"] >= base.accuracy_floor, (
                f"{name} accuracy {arm['accuracy']:.3f} below the "
                f"{base.accuracy_floor} floor")
            shadow = srv.obs_snapshot()["audit"]["shadow"]
            assert shadow["mode"] == "accuracy" and shadow["checks"] > 0
            assert shadow["divergences"] == 0, shadow
            arm["shadow"] = {k: shadow[k] for k in
                             ("mode", "checks", "divergences", "floor")}
            arm["agreement"] = shadow["agreement"]
        section[name] = arm
        emit(common.row(
            f"serve_predict_{name}", 1e6 / arm["qps"],
            f"acc={arm['accuracy']:.3f} msgs={arm['mean_messages']:.1f} "
            f"rounds={arm['mean_rounds']:.1f} "
            f"touched={arm['mean_shards_touched']:.2f}"))
    # the headline table: what one O(C) message per touched shard costs
    # in accuracy against the exact fold's extra round + (t-1) messages
    section["bill"] = [
        {"mode": name, "local_k": section[name]["local_k"],
         "accuracy": section[name]["accuracy"],
         "mean_messages": section[name]["mean_messages"],
         "mean_rounds": section[name]["mean_rounds"]}
        for name, _ in arms]
    return section


def _drive(srv, rng, bursts: int, centers=None) -> dict:
    """Closed-loop load: submit a burst, flush, repeat.  Burst sizes cycle
    through the bucket spectrum so padding and bucket choice both get
    exercised; latencies are per request (enqueue -> result).  With
    ``centers``, each burst's queries land near one random center (the
    clustered routing workload: a decode batch's positions are
    neighbors, so a micro-batch shares a destination — the touched-shard
    union stays small) instead of uniformly."""
    burst_sizes = [1, 3, 8, 16, 5, 16, 2, 16]
    lat, iters, rounds, msgs, touched = [], [], [], [], []
    n_queries = 0
    t0 = None
    for burst in range(WARM_BURSTS + bursts):
        if burst == WARM_BURSTS:
            t0 = time.perf_counter()
            srv.stats = type(srv.stats)()    # drop warmup counters
        bs = burst_sizes[burst % len(burst_sizes)]
        qs = rng.normal(size=(bs, DIM)).astype(np.float32)
        if centers is not None:
            qs += centers[rng.integers(0, len(centers))].astype(np.float32)
        ls = [L_MIX[(burst + j) % len(L_MIX)] for j in range(bs)]
        results = srv.query_batch(qs, ls)
        if burst >= WARM_BURSTS:
            n_queries += bs
            for r in results:
                lat.append(r.latency_s)
                iters.append(r.iterations)
                rounds.append(r.rounds)
                msgs.append(r.messages)
                touched.append(r.shards_touched)
    wall = time.perf_counter() - t0
    lat = np.asarray(lat)
    return {
        "queries": n_queries,
        "wall_s": wall,
        "qps": n_queries / wall,
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "mean_iterations": float(np.mean(iters)),
        "mean_rounds": float(np.mean(rounds)),
        "mean_messages": float(np.mean(msgs)),
        "mean_shards_touched": float(np.mean(touched)),
        "batches": srv.stats.batches,
        "padded_rows": srv.stats.padded_rows,
        "bucket_counts": {str(k): v
                          for k, v in sorted(srv.stats.bucket_counts.items())},
    }


def run(emit=print, out_path=None, smoke: bool = False,
        trace_out=None, prom_out=None, history=None) -> dict:
    """``smoke=True`` is the CI dry-run: tiny store, few bursts — proves
    the script end-to-end (build, warmup, drive, JSON emit) in seconds.
    ``history`` names the perf ledger (BENCH_history.jsonl) this run
    appends its summary row to; ``benchmarks/check_perf.py`` judges the
    row against the rolling baseline of prior rows."""
    n_points = common.K_MACHINES * 256 if smoke else N_POINTS
    bursts = 4 if smoke else BURSTS
    rng = np.random.default_rng(7)
    report = {
        "n_points": n_points, "dim": DIM, "l_max": L_MAX,
        "l_mix": list(L_MIX), "buckets": list(BUCKETS),
        "k_machines": common.K_MACHINES, "smoke": smoke,
    }
    for sampler in ("selection", "gather"):
        srv = _build_server(sampler, n_points)
        report[sampler] = _drive(srv, rng, bursts)
        report.setdefault("kernel_envelopes", {})[sampler] = srv.envelopes
        r = report[sampler]
        emit(common.row(
            f"serve_{sampler}_qps", 1e6 / r["qps"],
            f"qps={r['qps']:.1f} p50={r['p50_ms']:.2f}ms "
            f"p99={r['p99_ms']:.2f}ms rounds={r['mean_rounds']:.1f}"))
    # exact-vs-pruned routing A/B on the clustered workload: answers are
    # bit-identical (tests/test_routing.py enforces it); what this section
    # measures is the k-machine bill — mean messages strictly below the
    # exact route, shards_touched < k.
    report["routing"] = {}
    for route in ("exact", "pruned"):
        srv, centers = _build_routed_server(route, n_points)
        rng_route = np.random.default_rng(11)    # same load both routes
        report["routing"][route] = _drive(srv, rng_route, bursts,
                                          centers=centers)
        r = report["routing"][route]
        emit(common.row(
            f"serve_route_{route}_qps", 1e6 / r["qps"],
            f"qps={r['qps']:.1f} msgs={r['mean_messages']:.1f} "
            f"rounds={r['mean_rounds']:.1f} "
            f"shards_touched={r['mean_shards_touched']:.2f}"))
    # placement A/B (store/placement.py): can a *mutable* store's layout
    # prune like the static one?  balance / affinity / affinity+proximity
    # against the static cluster-contiguous baseline, pre and post
    # compaction.
    report["placement"] = _placement_section(
        bursts, per_shard=128 if smoke else 1024, emit=emit)
    # adaptive maintenance A/B (store/adaptive.py): on a drifting-cluster
    # stream, does pruned routing stay effective *before* any compaction?
    # no-maintenance vs re-tighten vs re-tighten+split vs the static
    # layout of the same final live set.
    report["adaptive"] = _adaptive_section(
        bursts,
        per_step=24 if smoke else 96,
        steps=6 if smoke else 12,
        window=2 if smoke else 4,
        retighten_every=16 if smoke else 64,
        emit=emit)
    # observability plane (src/repro/obs/): audited serving + the
    # exported flight-recorder trace + the instrumented-vs-off A/B
    report["obs"] = _obs_section(
        bursts, per_shard=64 if smoke else 512, emit=emit,
        trace_out=trace_out, prom_out=prom_out)
    # in-shard index A/B (store/index.py): exact vs approx on the
    # clustered and drifting workloads, recall floor + 3x candidate
    # reduction hard-asserted (ISSUE 8 acceptance)
    report["index"] = _index_section(
        bursts,
        per_shard=128 if smoke else 1024,
        per_step=24 if smoke else 96,
        steps=6 if smoke else 12,
        window=2 if smoke else 4,
        emit=emit)
    # label-prediction A/B (src/repro/predict/): exact fold hard-matched
    # to the single-machine oracle vote; one-message-per-shard ensemble
    # hard-held to messages == touched_shards and the accuracy floor
    report["predict"] = _predict_section(
        bursts, n_per_class=128 if smoke else 1024, emit=emit)
    common.stamp(report)
    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=2)
        emit(f"# wrote {out_path}")
    if history:
        row = perf_ledger.summarize(report)
        perf_ledger.append_row(row, history)
        emit(f"# appended perf row ({row['git_commit']}, "
             f"smoke={row['smoke']}) to {history}")
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes; CI dry-run (make bench-smoke)")
    ap.add_argument("--trace-out", default="BENCH_trace.jsonl",
                    help="flight-recorder span export (JSONL; "
                         "benchmarks/check_obs.py validates it)")
    ap.add_argument("--prom-out", default="BENCH_prom.txt",
                    help="Prometheus text exposition fetched from the "
                         "obs HTTP endpoint during the obs section")
    ap.add_argument("--history", default="BENCH_history.jsonl",
                    help="perf ledger to append this run's summary row "
                         "to ('' disables; benchmarks/check_perf.py "
                         "judges the row)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(emit=print, out_path=args.out, smoke=args.smoke,
        trace_out=args.trace_out, prom_out=args.prom_out,
        history=args.history)


if __name__ == "__main__":
    main()
