"""Micro-batched kNN service throughput/latency vs the gather baseline,
plus the exact-vs-pruned routing A/B.

Drives runtime/knn_server.py with a closed-loop offered load (bursts of
requests with per-request l drawn from a fixed mix), for both
``sampler="selection"`` (Algorithm 2, O(log l) rounds) and
``sampler="gather"`` (the paper's simple method via knn_simple, O(k*l)
values on the wire) — the paper's Figure 2 contrast restated as a serving
benchmark.  A second section serves a *clustered* store (one cluster per
shard, queries near cluster centers) under ``route="exact"`` vs
``route="pruned"`` (store/summaries.py): same bit-identical answers,
fewer touched shards and k-machine messages.  Emits CSV rows like every
other bench module plus ``BENCH_serve.json`` with sustained queries/sec,
p50/p99 request latency, and mean rounds/messages/shards_touched per
configuration.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src:. python benchmarks/bench_serve.py --out BENCH_serve.json
"""

try:
    from benchmarks import common  # noqa: F401  (claims the 8-device mesh)
except ImportError:  # run as a plain script: python benchmarks/bench_serve.py
    import common

import argparse
import json
import time

import numpy as np

from repro.configs.knn_service import CONFIG


# CPU-sized service shape: big enough that a datastore pass dominates the
# python batching overhead, small enough that the bench stays in seconds.
N_POINTS = common.K_MACHINES * 4096
DIM = 32
L_MAX = 32
L_MIX = (1, 4, 8, 32)          # per-request l rotation
BUCKETS = (1, 2, 4, 8, 16)
BURSTS = 24                    # measured dispatch bursts per sampler
WARM_BURSTS = 3


def _build_server(sampler: str, n_points: int):
    from repro.runtime import KnnServer
    rng = np.random.default_rng(0)
    pts = rng.normal(size=(n_points, DIM)).astype(np.float32)
    cfg = CONFIG.replace(
        dim=DIM, l=8, l_max=L_MAX, bucket_sizes=BUCKETS, sampler=sampler)
    srv = KnnServer(pts, cfg=cfg, mesh=common.kmachine_mesh(),
                    axis_name="x")
    srv.warmup()
    return srv


def _build_routed_server(route: str, n_points: int):
    """Clustered store, one cluster per shard (contiguous layout), for
    the exact-vs-pruned routing A/B — the same instance family the
    exactness harness proves bit-identical (repro.data.sharded_clusters)."""
    from repro.data import sharded_clusters
    from repro.runtime import KnnServer
    k = common.K_MACHINES
    pts, centers = sharded_clusters(k, n_points // k, DIM, seed=1)
    cfg = CONFIG.replace(dim=DIM, l=8, l_max=L_MAX, bucket_sizes=BUCKETS,
                         sampler="selection", route=route)
    srv = KnnServer(pts, cfg=cfg, mesh=common.kmachine_mesh(),
                    axis_name="x")
    srv.warmup()
    return srv, centers


def _drive(srv, rng, bursts: int, centers=None) -> dict:
    """Closed-loop load: submit a burst, flush, repeat.  Burst sizes cycle
    through the bucket spectrum so padding and bucket choice both get
    exercised; latencies are per request (enqueue -> result).  With
    ``centers``, each burst's queries land near one random center (the
    clustered routing workload: a decode batch's positions are
    neighbors, so a micro-batch shares a destination — the touched-shard
    union stays small) instead of uniformly."""
    burst_sizes = [1, 3, 8, 16, 5, 16, 2, 16]
    lat, iters, rounds, msgs, touched = [], [], [], [], []
    n_queries = 0
    t0 = None
    for burst in range(WARM_BURSTS + bursts):
        if burst == WARM_BURSTS:
            t0 = time.perf_counter()
            srv.stats = type(srv.stats)()    # drop warmup counters
        bs = burst_sizes[burst % len(burst_sizes)]
        qs = rng.normal(size=(bs, DIM)).astype(np.float32)
        if centers is not None:
            qs += centers[rng.integers(0, len(centers))].astype(np.float32)
        ls = [L_MIX[(burst + j) % len(L_MIX)] for j in range(bs)]
        results = srv.query_batch(qs, ls)
        if burst >= WARM_BURSTS:
            n_queries += bs
            for r in results:
                lat.append(r.latency_s)
                iters.append(r.iterations)
                rounds.append(r.rounds)
                msgs.append(r.messages)
                touched.append(r.shards_touched)
    wall = time.perf_counter() - t0
    lat = np.asarray(lat)
    return {
        "queries": n_queries,
        "wall_s": wall,
        "qps": n_queries / wall,
        "p50_ms": float(np.percentile(lat, 50) * 1e3),
        "p99_ms": float(np.percentile(lat, 99) * 1e3),
        "mean_iterations": float(np.mean(iters)),
        "mean_rounds": float(np.mean(rounds)),
        "mean_messages": float(np.mean(msgs)),
        "mean_shards_touched": float(np.mean(touched)),
        "batches": srv.stats.batches,
        "padded_rows": srv.stats.padded_rows,
        "bucket_counts": {str(k): v
                          for k, v in sorted(srv.stats.bucket_counts.items())},
    }


def run(emit=print, out_path=None, smoke: bool = False) -> dict:
    """``smoke=True`` is the CI dry-run: tiny store, few bursts — proves
    the script end-to-end (build, warmup, drive, JSON emit) in seconds."""
    n_points = common.K_MACHINES * 256 if smoke else N_POINTS
    bursts = 4 if smoke else BURSTS
    rng = np.random.default_rng(7)
    report = {
        "n_points": n_points, "dim": DIM, "l_max": L_MAX,
        "l_mix": list(L_MIX), "buckets": list(BUCKETS),
        "k_machines": common.K_MACHINES, "smoke": smoke,
    }
    for sampler in ("selection", "gather"):
        srv = _build_server(sampler, n_points)
        report[sampler] = _drive(srv, rng, bursts)
        report.setdefault("kernel_envelopes", {})[sampler] = srv.envelopes
        r = report[sampler]
        emit(common.row(
            f"serve_{sampler}_qps", 1e6 / r["qps"],
            f"qps={r['qps']:.1f} p50={r['p50_ms']:.2f}ms "
            f"p99={r['p99_ms']:.2f}ms rounds={r['mean_rounds']:.1f}"))
    # exact-vs-pruned routing A/B on the clustered workload: answers are
    # bit-identical (tests/test_routing.py enforces it); what this section
    # measures is the k-machine bill — mean messages strictly below the
    # exact route, shards_touched < k.
    report["routing"] = {}
    for route in ("exact", "pruned"):
        srv, centers = _build_routed_server(route, n_points)
        rng_route = np.random.default_rng(11)    # same load both routes
        report["routing"][route] = _drive(srv, rng_route, bursts,
                                          centers=centers)
        r = report["routing"][route]
        emit(common.row(
            f"serve_route_{route}_qps", 1e6 / r["qps"],
            f"qps={r['qps']:.1f} msgs={r['mean_messages']:.1f} "
            f"rounds={r['mean_rounds']:.1f} "
            f"shards_touched={r['mean_shards_touched']:.2f}"))
    common.stamp(report)
    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=2)
        emit(f"# wrote {out_path}")
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes; CI dry-run (make bench-smoke)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(emit=print, out_path=args.out, smoke=args.smoke)


if __name__ == "__main__":
    main()
