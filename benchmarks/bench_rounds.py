"""Theorems 2.2 / 2.4 — round complexity.

Empirically measures selection iterations (2 collective rounds each):
  * vs n          -> O(log n) scaling (Theorem 2.2)
  * vs l at fixed buffers after Algorithm-2 pruning -> O(log l),
    independent of k (Theorem 2.4) — swept over k = 2..8 machines
  * multi-pivot (beyond-paper) -> ~log-k-fold fewer iterations
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from benchmarks.common import kmachine_mesh, row
from repro.core.selection import SelectionResult, select_l_smallest
from repro.parallel.compat import shard_map


def _iters(mesh, k, n, l, seed=0, num_pivots=1, repeats=5):
    def fn(v, i, key):
        r = select_l_smallest(v, i, l, key, axis_name="x",
                              num_pivots=num_pivots)
        return r.iterations

    f = jax.jit(shard_map(
        fn, mesh=mesh, in_specs=(P(None, "x"), P(None, "x"), P(None)),
        out_specs=P()))
    rng = np.random.default_rng(seed)
    out = []
    for r in range(repeats):
        vals = rng.normal(size=(1, n)).astype(np.float32)
        ids = np.arange(n, dtype=np.int32)[None]
        out.append(int(f(vals, ids, jax.random.PRNGKey(seed + r))))
    return float(np.mean(out))


def run(emit=print):
    k = 8
    mesh = kmachine_mesh(k)

    # Theorem 2.2: iterations vs n (selecting the median)
    for n in (1 << 10, 1 << 13, 1 << 16):
        it = _iters(mesh, k, n, n // 2)
        emit(row(f"rounds/selection_n{n}", it,
                 f"iters={it:.1f};2logn={2*np.log2(n):.1f};"
                 f"rounds={2*it:.0f}"))

    # Theorem 2.4: k-independence — fixed l, growing k
    for kk in (2, 4, 8):
        m = kmachine_mesh(kk)
        it = _iters(m, kk, kk * 512, 128)
        emit(row(f"rounds/k_independence_k{kk}", it,
                 f"iters={it:.1f};l=128"))

    # beyond-paper multi-pivot
    n = 1 << 14
    it1 = _iters(mesh, k, n, n // 2, num_pivots=1)
    itk = _iters(mesh, k, n, n // 2, num_pivots=k)
    emit(row("rounds/multi_pivot_speedup", itk,
             f"single={it1:.1f};multi={itk:.1f};ratio={it1/itk:.2f}"))


if __name__ == "__main__":
    run()
