"""Paper Figure 2 — Algorithm 2 vs the simple method.

The paper reports wall-clock ratio (simple / Algorithm 2) up to ~80x at
k = 128 on an MPI cluster.  On this single CPU host the k machines are
simulated shards, so wall-clock favors neither side realistically;
we therefore report BOTH:

  * measured wall-time ratio on the simulated mesh (for the record), and
  * the bytes-on-the-wire ratio — the model-level quantity the paper's
    speedup derives from: simple moves k*l values to one machine,
    Algorithm 2 moves O(k log l) scalars.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from benchmarks.common import kmachine_mesh, row, time_fn
import repro.core as core
from repro.core import sampling
from repro.parallel.compat import shard_map


def _bytes_simple(k: int, l: int) -> int:
    # gather of (dist f32 + id i32) x l per machine
    return k * l * 8


def _bytes_alg2(k: int, l: int, iters: float) -> float:
    s = sampling.sample_count(l)
    per_iter = k * (3 * 4)          # pivot gather: (val, id, count) scalars
    per_iter += k * 4               # count psum contribution
    return k * s * 4 + iters * per_iter + k * 2 * 4


def run(emit=print):
    k = 8
    mesh = kmachine_mesh(k)
    rng = np.random.default_rng(0)
    dim = 16
    n = k * (1 << 14)
    pts = (rng.random((n, dim)) * 2**16).astype(np.float32)
    pids = np.arange(n, dtype=np.int32)

    for l in (16, 64, 256, 1024):
        q = rng.normal(size=(1, dim)).astype(np.float32) * 2**8

        def alg2(p, i, qq, key):
            r = core.knn_query(p, i, qq, l, key, axis_name="x")
            return r.dists, r.selection.iterations

        def simple(p, i, qq):
            return core.knn_simple(p, i, qq, l, axis_name="x")

        f2 = jax.jit(shard_map(
            alg2, mesh=mesh, in_specs=(P("x"), P("x"), P(None), P(None)),
            out_specs=(P(None), P())))
        fs = jax.jit(shard_map(
            simple, mesh=mesh, in_specs=(P("x"), P("x"), P(None)),
            out_specs=(P(None), P(None))))

        key = jax.random.PRNGKey(1)
        t2 = time_fn(lambda: f2(pts, pids, q, key), repeats=10)
        ts = time_fn(lambda: fs(pts, pids, q), repeats=10)
        _, iters = f2(pts, pids, q, key)
        b_s = _bytes_simple(k, l)
        b_2 = _bytes_alg2(k, l, float(iters))
        emit(row(f"fig2/l{l}", t2 * 1e6,
                 f"alg2_us={t2*1e6:.0f};simple_us={ts*1e6:.0f};"
                 f"time_ratio={ts/t2:.2f};bytes_simple={b_s};"
                 f"bytes_alg2={b_2:.0f};bytes_ratio={b_s/b_2:.1f};"
                 f"iters={float(iters):.0f}"))


if __name__ == "__main__":
    run()
